"""Pure-jnp / pure-python correctness oracles for the attention kernels.

Implements, in the most literal possible form:
  * safe-softmax attention (the mathematical ground truth),
  * Alg. 1  (baseline FlashAttention, incremental division),
  * Alg. 2  (FlashAttention2, lazy division),
  * Alg. 3  (FLASH-D, sigmoid-hidden division)  -- the paper's kernel,
  * the blocked (tiled) generalization of FLASH-D used by the Pallas kernel.

All recursions are written exactly as the paper states them so the Pallas
kernels and the Rust kernels can be validated against an unambiguous oracle.
Everything here is build/test-time only; nothing is imported at runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_ref(q, k, v, sm_scale=1.0, causal=False):
    """Safe-softmax attention. q: (Lq, D), k/v: (Lk, D). Returns (Lq, D)."""
    s = (q @ k.T) * sm_scale
    if causal:
        lq, lk = s.shape
        mask = jnp.tril(jnp.ones((lq, lk), dtype=bool), k=lk - lq)
        s = jnp.where(mask, s, NEG_INF)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v


def mha_ref(q, k, v, sm_scale=1.0, causal=False):
    """Multi-head reference. q,k,v: (H, L, D)."""
    return jax.vmap(lambda qh, kh, vh: attention_ref(qh, kh, vh, sm_scale, causal))(q, k, v)


# ---------------------------------------------------------------------------
# Literal per-element recursions (numpy, float64) for a single query vector.
# ---------------------------------------------------------------------------

def _sigmoid(x: float) -> float:
    """Branching sigmoid: never exponentiates a positive argument.  This is
    the float analog of the paper's saturation argument — outside the active
    region the exponential is never evaluated."""
    if x >= 0.0:
        return 1.0 / (1.0 + np.exp(-x))
    e = np.exp(x)
    return e / (1.0 + e)


def _log_sigmoid(x: float) -> float:
    """ln sigma(x), stable on both tails (~x for x<<0, ~0 for x>>0)."""
    if x >= 0.0:
        return -np.log1p(np.exp(-x))
    return x - np.log1p(np.exp(x))

def flash1_single(q, k, v):
    """Alg. 1: baseline FlashAttention with incremental softmax division."""
    q, k, v = np.asarray(q, np.float64), np.asarray(k, np.float64), np.asarray(v, np.float64)
    n = k.shape[0]
    m = -np.inf
    ell = 0.0
    o = np.zeros(v.shape[1], np.float64)
    for i in range(n):
        s = float(q @ k[i])
        m_new = max(m, s)
        ell_new = ell * np.exp(m - m_new) + np.exp(s - m_new)
        o = o * (ell * np.exp(m - m_new) / ell_new) + v[i] * (np.exp(s - m_new) / ell_new)
        m, ell = m_new, ell_new
    return o


def flash2_single(q, k, v):
    """Alg. 2: FlashAttention2 with lazy (final) division."""
    q, k, v = np.asarray(q, np.float64), np.asarray(k, np.float64), np.asarray(v, np.float64)
    n = k.shape[0]
    m = -np.inf
    ell = 0.0
    o = np.zeros(v.shape[1], np.float64)
    for i in range(n):
        s = float(q @ k[i])
        m_new = max(m, s)
        o = o * np.exp(m - m_new) + v[i] * np.exp(s - m_new)
        ell = ell * np.exp(m - m_new) + np.exp(s - m_new)
        m = m_new
    return o / ell


def flashd_single(q, k, v, clip=None):
    """Alg. 3: FLASH-D. The softmax division is hidden in the sigmoid.

    With ``clip=(lo, hi)`` the paper's saturation rule is applied: when the
    sigmoid argument falls below ``lo`` the update is skipped entirely
    (w ~ 0); above ``hi`` the output is replaced by the value vector
    (w ~ 1). ``clip=None`` computes the exact recursion. Returns
    ``(o, skipped)`` when clipping, else ``o``.
    """
    q, k, v = np.asarray(q, np.float64), np.asarray(k, np.float64), np.asarray(v, np.float64)
    n = k.shape[0]
    o = np.zeros(v.shape[1], np.float64)
    s_prev = 0.0
    ln_w = 0.0
    skipped = 0
    for i in range(n):
        s = float(q @ k[i])
        if i == 0:
            w = 1.0
            ln_w = 0.0
        else:
            x = s - s_prev + ln_w
            if clip is not None and x <= clip[0]:
                skipped += 1
                s_prev = s
                # ln sigmoid(x) ~ x on the low tail: the ln unit is bypassed
                # and the argument passes through as the carried ln w
                ln_w = x
                continue
            if clip is not None and x >= clip[1]:
                skipped += 1
                o = v[i].copy()
                s_prev = s
                ln_w = 0.0  # w ~ 1
                continue
            w = _sigmoid(x)
            ln_w = _log_sigmoid(x)
        o = o + (v[i] - o) * w  # Eq. (12): one mul, one add, one sub
        s_prev = s
    return (o, skipped) if clip is not None else o


def flashd_blocked_ref(q, k, v, block_k, sm_scale=1.0):
    """Tiled FLASH-D (the form the Pallas kernel implements), single query
    block. q: (Lq, D), k/v: (Lk, D).

    Carry between KV blocks is the log-sum-exp ``lam`` of all scores seen so
    far; each new block contributes through the *sigmoid of LSE differences*:

        W    = sigmoid(lam_b - lam)          # block-granular FLASH-D weight
        o'   = o + (o_b - o) * W             # Eq. (12) at block granularity
        lam' = lam_b - log(W)                #   = logaddexp(lam, lam_b)

    which degenerates to Alg. 3 exactly when ``block_k == 1``.
    """
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    lq, d = q.shape
    lk = k.shape[0]
    o = np.zeros((lq, d), np.float64)
    lam = np.full((lq,), -np.inf)
    for j0 in range(0, lk, block_k):
        kb = k[j0:j0 + block_k]
        vb = v[j0:j0 + block_k]
        s = (q @ kb.T) * sm_scale                      # (lq, B)
        mb = s.max(axis=1)
        pb = np.exp(s - mb[:, None])
        lb = pb.sum(axis=1)
        lam_b = mb + np.log(lb)                        # block LSE
        ob = (pb / lb[:, None]) @ vb                   # block-local softmax @ V
        with np.errstate(over="ignore"):
            w = 1.0 / (1.0 + np.exp(-(lam_b - lam)))   # sigmoid(LSE diff)
        o = o + (ob - o) * w[:, None]
        lam = np.logaddexp(lam, lam_b)
    return o
