"""Layer-1 Pallas kernel: FLASH-D (Alg. 3), tiled for the TPU memory
hierarchy, executed with ``interpret=True`` so the lowered HLO runs on any
PJRT backend (including the Rust CPU client).

Hardware adaptation (DESIGN.md §5): the paper's ASIC datapath processes one
key/value vector per cycle with a scalar sigmoid-weight recursion.  On a
TPU-shaped target the natural unit of streaming is a *KV block* staged
HBM -> VMEM by the BlockSpec; the FLASH-D recursion generalizes cleanly to
block granularity because the carried state ``(s_prev, ln w)`` is exactly a
log-sum-exp in disguise (Eq. (8) gives  e^{s_i}/w_i = sum_j e^{s_j}):

    lam      = s_prev - ln w          # LSE of all scores seen so far
    W        = sigmoid(lam_b - lam)   # block-granular FLASH-D weight
    o'       = o + (o_b - o) * W      # Eq. (12): one FMA per element
    lam'     = logaddexp(lam, lam_b)  #   = lam_b - ln W

The per-element Alg. 3 is the ``block_k == 1`` special case; equality is
checked in python/tests/test_kernel.py against ref.flashd_single.

No running maximum is carried between blocks and no epilogue division is
performed — the two structural savings the paper claims — while the block-
local softmax stays numerically safe via its own private max.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flashd_kernel(q_ref, k_ref, v_ref, kvlen_ref, o_ref, o_acc, lam_ref, *,
                   sm_scale, causal, block_q, block_k, num_kv_blocks):
    """One (head, q-block, kv-block) grid step.

    Scratch carries (o_acc, lam) across the sequential kv-block axis.
    ``kvlen_ref`` holds the valid KV length (serving pads K/V to the
    compiled sequence length; keys at index >= kv_len are masked out).
    """
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    # Reset the carry at the start of each query block's kv sweep.
    @pl.when(ki == 0)
    def _init():
        o_acc[...] = jnp.zeros_like(o_acc)
        lam_ref[...] = jnp.full_like(lam_ref, NEG_INF)

    q = q_ref[0].astype(jnp.float32)          # (block_q, d)
    k = k_ref[0].astype(jnp.float32)          # (block_k, d)
    v = v_ref[0].astype(jnp.float32)          # (block_k, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if causal:
        rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        s = jnp.where(rows >= cols, s, NEG_INF)
    s = jnp.where(cols < kvlen_ref[0, 0], s, NEG_INF)

    # Block-local softmax statistics (private max keeps exp() in range).
    mb = jnp.max(s, axis=1)                               # (block_q,)
    pb = jnp.exp(s - mb[:, None])                         # (block_q, block_k)
    lb = jnp.sum(pb, axis=1)                              # (block_q,)
    lam_b = mb + jnp.log(lb)                              # block LSE
    ob = jax.lax.dot_general(pb / lb[:, None], v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    lam = lam_ref[...]
    lam_new = jnp.logaddexp(lam, lam_b)
    # W = sigmoid(lam_b - lam); computed as exp(lam_b - lam') which is the
    # identical quantity evaluated from the already-needed carry update.
    w = jnp.exp(lam_b - lam_new)                          # in (0, 1]
    o_acc[...] = o_acc[...] + (ob - o_acc[...]) * w[:, None]   # Eq. (12)
    lam_ref[...] = lam_new

    @pl.when(ki == num_kv_blocks - 1)
    def _emit():
        o_ref[0] = o_acc[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "causal", "block_q", "block_k"))
def flashd_attention(q, k, v, kv_len=None, sm_scale=1.0, causal=False,
                     block_q=64, block_k=64):
    """FLASH-D attention. q, k, v: (H, L, D) -> (H, Lq, D).

    ``kv_len``: optional (1, 1) int32 array with the valid KV prefix length
    (used by the serving path, which pads K/V to the compiled shape).

    interpret=True: real-TPU lowering would emit a Mosaic custom call the
    CPU PJRT plugin cannot execute; interpret mode lowers to plain HLO.
    """
    h, lq, d = q.shape
    lk = k.shape[1]
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    assert lq % block_q == 0 and lk % block_k == 0, (lq, block_q, lk, block_k)
    num_kv_blocks = lk // block_k
    if kv_len is None:
        kv_len = jnp.full((1, 1), lk, jnp.int32)

    grid = (h, lq // block_q, lk // block_k)
    return pl.pallas_call(
        functools.partial(_flashd_kernel, sm_scale=sm_scale, causal=causal,
                          block_q=block_q, block_k=block_k,
                          num_kv_blocks=num_kv_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda hh, qi, ki: (hh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda hh, qi, ki: (hh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda hh, qi, ki: (hh, ki, 0)),
            pl.BlockSpec((1, 1), lambda hh, qi, ki: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda hh, qi, ki: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, lq, d), q.dtype),
        scratch_shapes=[
            # f32 accumulators live in VMEM scratch across the kv sweep.
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, kv_len)
