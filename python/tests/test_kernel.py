"""Layer-1 correctness: Pallas kernels vs the pure-jnp/numpy oracles.

Hypothesis sweeps shapes/dtypes/block sizes and asserts allclose against
ref.py — the CORE correctness signal for the compile path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash2 import flash2_attention
from compile.kernels.flashd import flashd_attention

jax.config.update("jax_enable_x64", False)


def rand_qkv(rng, h, l, d, scale=1.0, dtype=np.float32):
    q = rng.normal(0, scale, size=(h, l, d)).astype(dtype)
    k = rng.normal(0, scale, size=(h, l, d)).astype(dtype)
    v = rng.normal(0, scale, size=(h, l, d)).astype(dtype)
    return jnp.array(q), jnp.array(k), jnp.array(v)


# ---------------------------------------------------------------------------
# Algorithmic equivalence of the paper's three formulations (float64, exact)
# ---------------------------------------------------------------------------

class TestAlgorithmEquivalence:
    @pytest.mark.parametrize("n,d", [(1, 4), (7, 8), (64, 16), (256, 8)])
    def test_flash1_matches_softmax(self, n, d):
        rng = np.random.default_rng(n * 31 + d)
        q = rng.normal(size=(d,))
        k = rng.normal(size=(n, d))
        v = rng.normal(size=(n, d))
        want = np.array(ref.attention_ref(q[None], k, v))[0]
        np.testing.assert_allclose(ref.flash1_single(q, k, v), want, rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("n,d", [(1, 4), (7, 8), (64, 16), (256, 8)])
    def test_flash2_matches_flash1(self, n, d):
        rng = np.random.default_rng(n * 17 + d)
        q = rng.normal(size=(d,))
        k = rng.normal(size=(n, d))
        v = rng.normal(size=(n, d))
        np.testing.assert_allclose(ref.flash2_single(q, k, v),
                                   ref.flash1_single(q, k, v), rtol=1e-12)

    @pytest.mark.parametrize("n,d", [(1, 4), (7, 8), (64, 16), (256, 8)])
    def test_flashd_matches_flash1(self, n, d):
        """The paper's central claim: Alg. 3 == Alg. 1 with no approximation."""
        rng = np.random.default_rng(n * 13 + d)
        q = rng.normal(size=(d,))
        k = rng.normal(size=(n, d))
        v = rng.normal(size=(n, d))
        np.testing.assert_allclose(ref.flashd_single(q, k, v),
                                   ref.flash1_single(q, k, v), rtol=1e-9, atol=1e-12)

    def test_flashd_stable_without_max_subtraction(self):
        """Huge scores that would overflow naive exp() are fine in FLASH-D."""
        rng = np.random.default_rng(0)
        d, n = 8, 64
        q = rng.normal(size=(d,)) * 10.0
        k = rng.normal(size=(n, d)) * 10.0   # scores ~ O(several hundred)
        v = rng.normal(size=(n, d))
        out = ref.flashd_single(q, k, v)
        want = np.array(ref.attention_ref(q[None], k, v))[0]
        assert np.all(np.isfinite(out))
        np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-9)

    def test_blocked_equals_elementwise_when_block1(self):
        rng = np.random.default_rng(7)
        d, n = 8, 32
        q = rng.normal(size=(1, d))
        k = rng.normal(size=(n, d))
        v = rng.normal(size=(n, d))
        blocked = ref.flashd_blocked_ref(q, k, v, block_k=1)[0]
        single = ref.flashd_single(q[0], k, v)
        np.testing.assert_allclose(blocked, single, rtol=1e-12)

    @pytest.mark.parametrize("block_k", [1, 2, 8, 32])
    def test_blocked_block_size_invariance(self, block_k):
        rng = np.random.default_rng(block_k)
        q = rng.normal(size=(4, 8))
        k = rng.normal(size=(32, 8))
        v = rng.normal(size=(32, 8))
        out = ref.flashd_blocked_ref(q, k, v, block_k=block_k)
        want = np.array(ref.attention_ref(q, k, v))
        # attention_ref is float32 (jnp default); compare at f32 precision
        np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)

    def test_skip_criterion_preserves_output(self):
        """Static [-6, 11] clipping changes outputs only negligibly."""
        rng = np.random.default_rng(3)
        d, n = 16, 128
        q = rng.normal(size=(d,))
        k = rng.normal(size=(n, d))
        v = rng.normal(size=(n, d))
        exact = ref.flashd_single(q, k, v)
        clipped, skipped = ref.flashd_single(q, k, v, clip=(-6.0, 11.0))
        np.testing.assert_allclose(clipped, exact, rtol=1e-2, atol=5e-3)
        assert 0 <= skipped <= n


# ---------------------------------------------------------------------------
# Pallas kernels vs oracle
# ---------------------------------------------------------------------------

class TestPallasKernels:
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize("h,l,d", [(1, 32, 8), (2, 64, 16), (4, 128, 32)])
    def test_flashd_pallas(self, h, l, d, causal):
        rng = np.random.default_rng(h * l + d)
        q, k, v = rand_qkv(rng, h, l, d)
        scale = d ** -0.5
        out = flashd_attention(q, k, v, sm_scale=scale, causal=causal,
                               block_q=min(32, l), block_k=min(32, l))
        want = ref.mha_ref(q, k, v, sm_scale=scale, causal=causal)
        np.testing.assert_allclose(np.array(out), np.array(want), rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize("h,l,d", [(1, 32, 8), (2, 64, 16), (4, 128, 32)])
    def test_flash2_pallas(self, h, l, d, causal):
        rng = np.random.default_rng(h + l + d)
        q, k, v = rand_qkv(rng, h, l, d)
        scale = d ** -0.5
        out = flash2_attention(q, k, v, sm_scale=scale, causal=causal,
                               block_q=min(32, l), block_k=min(32, l))
        want = ref.mha_ref(q, k, v, sm_scale=scale, causal=causal)
        np.testing.assert_allclose(np.array(out), np.array(want), rtol=2e-5, atol=2e-5)

    def test_flashd_equals_flash2_bitwise_shape(self):
        """Both kernels agree with each other (not just with the oracle)."""
        rng = np.random.default_rng(42)
        q, k, v = rand_qkv(rng, 2, 64, 16)
        a = flashd_attention(q, k, v, sm_scale=0.25, block_q=32, block_k=32)
        b = flash2_attention(q, k, v, sm_scale=0.25, block_q=32, block_k=32)
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=2e-5, atol=2e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        h=st.sampled_from([1, 2, 4]),
        lpow=st.integers(4, 7),                    # L in {16..128}
        d=st.sampled_from([8, 16, 32]),
        bq=st.sampled_from([8, 16, 32]),
        bk=st.sampled_from([8, 16, 32]),
        scale=st.floats(0.05, 2.0),
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_flashd_hypothesis_sweep(self, h, lpow, d, bq, bk, scale, causal, seed):
        l = 2 ** lpow
        bq, bk = min(bq, l), min(bk, l)
        rng = np.random.default_rng(seed)
        q, k, v = rand_qkv(rng, h, l, d, scale=2.0)
        out = flashd_attention(q, k, v, sm_scale=scale, causal=causal,
                               block_q=bq, block_k=bk)
        want = ref.mha_ref(q, k, v, sm_scale=scale, causal=causal)
        np.testing.assert_allclose(np.array(out), np.array(want), rtol=5e-4, atol=5e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        dtype=st.sampled_from(["float32", "bfloat16"]),
        seed=st.integers(0, 2**16),
    )
    def test_flashd_dtypes(self, dtype, seed):
        rng = np.random.default_rng(seed)
        q, k, v = rand_qkv(rng, 2, 64, 16)
        q = q.astype(dtype); k = k.astype(dtype); v = v.astype(dtype)
        out = flashd_attention(q, k, v, sm_scale=0.25, block_q=32, block_k=32)
        assert out.dtype == jnp.dtype(dtype)
        want = ref.mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), sm_scale=0.25)
        tol = 5e-2 if dtype == "bfloat16" else 5e-5
        np.testing.assert_allclose(np.array(out, np.float32), np.array(want),
                                   rtol=tol, atol=tol)

    def test_extreme_scores_no_nan(self):
        """No max-subtraction needed: large-magnitude scores stay finite."""
        rng = np.random.default_rng(1)
        q, k, v = rand_qkv(rng, 1, 32, 8, scale=30.0)  # scores O(1000s)
        out = flashd_attention(q, k, v, sm_scale=1.0, block_q=32, block_k=32)
        assert np.all(np.isfinite(np.array(out)))
        want = ref.mha_ref(q, k, v, sm_scale=1.0)
        np.testing.assert_allclose(np.array(out), np.array(want), rtol=1e-4, atol=1e-4)
