"""AOT path tests: lowering produces loadable HLO text, the FDW weight
format round-trips, and the manifest is consistent with the model ABI.
"""

import json
import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M
from compile.kernels.flashd import flashd_attention


def read_fdw(path):
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == b"FDW1"
    (n,) = struct.unpack_from("<I", data, 4)
    off = 8
    out = []
    for _ in range(n):
        (nl,) = struct.unpack_from("<H", data, off); off += 2
        name = data[off:off + nl].decode(); off += nl
        (nd,) = struct.unpack_from("<B", data, off); off += 1
        dims = struct.unpack_from(f"<{nd}I", data, off); off += 4 * nd
        cnt = int(np.prod(dims)) if nd else 1
        arr = np.frombuffer(data, "<f4", cnt, off).reshape(dims); off += 4 * cnt
        out.append((name, arr))
    return out


def test_fdw_roundtrip():
    rng = np.random.default_rng(0)
    named = [("a", rng.normal(size=(3, 4)).astype(np.float32)),
             ("deep.name", rng.normal(size=(7,)).astype(np.float32)),
             ("scalarish", rng.normal(size=(1,)).astype(np.float32))]
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "w.fdw")
        aot.write_fdw(p, named)
        back = read_fdw(p)
    assert [n for n, _ in back] == [n for n, _ in named]
    for (_, a), (_, b) in zip(named, back):
        np.testing.assert_array_equal(a, b)


def test_hlo_text_lowering_parses():
    """Lowered text must be plain HLO the 0.5.1 parser accepts: it should
    start with an HloModule header and contain an ENTRY computation."""
    spec = jax.ShapeDtypeStruct((2, 32, 8), jnp.float32)
    lowered = jax.jit(
        lambda q, k, v: (flashd_attention(q, k, v, sm_scale=0.35,
                                          block_q=16, block_k=16),)
    ).lower(spec, spec, spec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # the interpret-mode kernel must not leave an unexecutable custom-call
    assert "custom-call" not in text.lower() or "Sharding" in text


def test_manifest_train_io_arity():
    """Manifest ABI: train_step inputs = 3 * |params| + step + tokens."""
    cfg = M.MODEL_ZOO["phi-tiny"]
    nspec = len(M.param_spec(cfg))
    manifest_path = os.path.join(os.path.dirname(__file__), "..", "..",
                                 "artifacts", "manifest.json")
    if not os.path.exists(manifest_path):
        import pytest
        pytest.skip("artifacts not built")
    man = json.load(open(manifest_path))
    if "train_step_phi-tiny" not in man["artifacts"]:
        import pytest
        pytest.skip("phi-tiny not lowered")
    art = man["artifacts"]["train_step_phi-tiny"]
    assert len(art["inputs"]) == 3 * nspec + 2
    assert art["n_outputs"] == 3 * nspec + 1
    spec = man["models"]["phi-tiny"]["param_spec"]
    assert [e["name"] for e in spec] == [n for n, _ in M.param_spec(cfg)]
