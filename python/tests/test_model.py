"""Layer-2 correctness: model shapes, loss behaviour, train-step dynamics,
and the scan-form FLASH-D attention used in the training graph.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.ModelConfig(vocab_size=64, seq_len=32, d_model=32, n_heads=2,
                    n_layers=2, d_ff=64, block_q=16, block_k=16)


def test_param_spec_shapes_consistent():
    params = M.init_params(CFG, seed=0)
    for (name, shape), p in zip(M.param_spec(CFG), params):
        assert tuple(p.shape) == tuple(shape), name


def test_n_params_counts():
    assert M.n_params(CFG) == sum(int(np.prod(s)) for _, s in M.param_spec(CFG))


def test_scan_attention_matches_ref():
    rng = np.random.default_rng(0)
    h, l, d = 2, 32, 16
    q = jnp.array(rng.normal(size=(h, l, d)), jnp.float32)
    k = jnp.array(rng.normal(size=(h, l, d)), jnp.float32)
    v = jnp.array(rng.normal(size=(h, l, d)), jnp.float32)
    out = M.flashd_attention_scan(q, k, v, sm_scale=0.25, causal=True, block_k=8)
    want = ref.mha_ref(q, k, v, sm_scale=0.25, causal=True)
    np.testing.assert_allclose(np.array(out), np.array(want), rtol=2e-5, atol=2e-5)


def test_scan_attention_block_invariance():
    rng = np.random.default_rng(1)
    h, l, d = 1, 32, 8
    q = jnp.array(rng.normal(size=(h, l, d)), jnp.float32)
    k = jnp.array(rng.normal(size=(h, l, d)), jnp.float32)
    v = jnp.array(rng.normal(size=(h, l, d)), jnp.float32)
    outs = [np.array(M.flashd_attention_scan(q, k, v, 0.3, True, block_k=b))
            for b in (4, 8, 16, 32)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


def test_forward_shapes():
    params = M.init_params(CFG, 0)
    toks = jnp.arange(CFG.seq_len, dtype=jnp.int32) % CFG.vocab_size
    logits = M.forward(CFG, params, toks)
    assert logits.shape == (CFG.seq_len, CFG.vocab_size)
    assert np.all(np.isfinite(np.array(logits)))


def test_forward_pallas_matches_scan():
    """The inference artifact (Pallas kernel) and the training graph
    (scan recursion) compute the same forward pass."""
    params = M.init_params(CFG, 0)
    toks = (jnp.arange(CFG.seq_len, dtype=jnp.int32) * 7) % CFG.vocab_size
    a = M.forward(CFG, params, toks, use_pallas=False)
    b = M.forward(CFG, params, toks, use_pallas=True)
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=2e-4, atol=2e-4)


def test_causality():
    """Changing a future token must not change past logits."""
    params = M.init_params(CFG, 0)
    toks = jnp.zeros((CFG.seq_len,), jnp.int32)
    toks2 = toks.at[CFG.seq_len - 1].set(5)
    a = M.forward(CFG, params, toks)
    b = M.forward(CFG, params, toks2)
    np.testing.assert_allclose(np.array(a[:-1]), np.array(b[:-1]), rtol=1e-5, atol=1e-6)


def test_loss_near_uniform_at_init():
    params = M.init_params(CFG, 0)
    rng = np.random.default_rng(0)
    toks = jnp.array(rng.integers(0, CFG.vocab_size, size=(2, CFG.seq_len)), jnp.int32)
    loss = float(M.loss_fn(CFG, params, toks))
    assert abs(loss - np.log(CFG.vocab_size)) < 1.0


def test_train_step_reduces_loss_on_fixed_batch():
    params = M.init_params(CFG, 0)
    zeros = [jnp.zeros_like(p) for p in params]
    m, v = list(zeros), list(zeros)
    tcfg = M.TrainConfig(lr=1e-2)
    rng = np.random.default_rng(0)
    toks = jnp.array(rng.integers(0, CFG.vocab_size, size=(4, CFG.seq_len)), jnp.int32)

    step_fn = jax.jit(lambda p, m, v, s: M.train_step(CFG, tcfg, p, m, v, s, toks))
    losses = []
    step = jnp.int32(0)
    for i in range(12):
        params, m, v, loss = step_fn(params, m, v, step + i)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, losses


def test_train_step_grad_clip_finite():
    params = [p * 50.0 for p in M.init_params(CFG, 1)]   # pathological init
    zeros = [jnp.zeros_like(p) for p in params]
    tcfg = M.TrainConfig()
    toks = jnp.ones((2, CFG.seq_len), jnp.int32)
    nps, nm, nv, loss = M.train_step(CFG, tcfg, params, list(zeros), list(zeros),
                                     jnp.int32(0), toks)
    for p in nps:
        assert np.all(np.isfinite(np.array(p)))


@pytest.mark.parametrize("name", list(M.MODEL_ZOO))
def test_zoo_configs_valid(name):
    cfg = M.MODEL_ZOO[name]
    assert cfg.d_model % cfg.n_heads == 0
    assert cfg.seq_len % cfg.block_k == 0
    assert M.n_params(cfg) > 0
